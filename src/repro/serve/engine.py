"""Serving engine: chunked + ragged admission prefill and ragged batched
decode with slot-based continuous batching, plus the A^3 approximate
decode path.

The engine holds a fixed number of request *slots*. Every engine tick
runs the admission state machine::

    admit -> chunked prefill -> (A^3 re-sort) -> decode

* **Admit.** Queued requests claim free slots and enter the PREFILLING
  phase with a per-slot prompt cursor. No forward pass and no cache
  work runs at admit time — the slot's first chunk dispatch zeroes its
  ring rows in-graph, so chunked prefill reproduces the whole-prompt
  prefill cache state without a host-side reset copy.
* **Chunked ragged prefill — one dispatch per tick.** All PREFILLING
  slots advance by at most ``prefill_chunk`` prompt tokens in a *single*
  jitted ``prefill_chunk`` dispatch: a padded ``[slots, chunk]`` token
  block with per-slot start positions and lengths (lanes not prefilling
  ride along with length 0 and their cache rows pass through
  untouched). Long prompts therefore never stall decoding slots for
  more than one chunk, and multiple queued prompts prefill together
  instead of one ``decoder.prefill`` call per admit.
  ``stats["prefill_dispatches"]`` counts these dispatches; it is at most
  ``stats["ticks"]`` by construction. With ``prefill_chunk=None`` (or
  for archs with recurrent blocks, where chunked prefill is
  unsupported) admission falls back to one whole-prompt
  ``decoder.prefill`` per admit.
* **Decode — one dispatch per tick.** ``decode_step`` takes a per-slot
  position vector, so DECODING slots at arbitrary position skew advance
  in a single jitted call. ``stats["decode_dispatches"]`` equals
  ``stats["decode_steps"]`` by construction.
* **Cache donation.** Both the prefill-chunk and decode jits donate the
  KV cache argument, so the ring buffers update in place instead of
  being copied each tick.
* **One host read per tick.** ``_maybe_resort`` fetches all segments'
  ``sorted_upto`` watermarks in a single ``device_get`` and batches the
  re-sorts of all due slots per segment. Slots still PREFILLING are
  skipped — chunked prefill maintains their sort incrementally.

A^3 state at serve time: the paper's "comprehension-time" preprocessing
maps to prefill — the prompt's keys are column-sorted per slot and
reused across all decode steps (amortization argument of SSIV-C). With
chunked prefill the sort stays once-per-prompt: the dispatch of a
prompt's *final* chunk folds the completed ring into the per-column
sorted matrices and advances the ``sorted_upto`` watermark (a
``lax.cond`` skips the sort on every other tick — nothing reads a
PREFILLING slot's sort). Tokens generated after prefill form the
*fresh tail*, always treated as candidates (exact attention) until a
periodic re-sort folds them in.

``make_serve_step`` / ``make_prefill_chunk_step`` build the jitted
dispatches used by both the engine and the multi-pod dry-run (they are
what the ``decode_*`` / chunked-prefill shapes lower).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import A3Config, A3Mode, ModelConfig, ServeConfig
from repro.core.candidate_selection import sort_key_columns
from repro.models import decoder


def make_serve_step(
    cfg: ModelConfig,
    a3: A3Config = A3Config(),
    *,
    use_kernel: bool = False,
) -> Callable:
    """Returns step(params, cache, token [B], pos scalar or [B]) ->
    (logits [B, Vp], new_cache)."""

    def step(params, cache, token, pos):
        return decoder.decode_step(params, cfg, cache, token, pos, a3=a3,
                                   use_kernel=use_kernel)

    return step


def make_prefill_chunk_step(cfg: ModelConfig, *, a3: bool = False,
                            update_sort: bool = True) -> Callable:
    """Returns step(params, cache, tokens [B, C], pos [B], length [B],
    sort_lanes [B]) -> (logits [B, Vp], new_cache) — the ragged
    chunked-prefill dispatch. ``sort_lanes`` marks lanes on their final
    chunk (A^3: fold the completed prompt into the column sort);
    ``update_sort=False`` builds the cheaper specialization that treats
    the sorted-key leaves as read-only (dispatched on ticks where no
    lane finishes its prompt)."""

    def step(params, cache, tokens, pos, length, sort_lanes):
        return decoder.prefill_chunk(params, cfg, cache, tokens, pos,
                                     length, a3=a3, sort_lanes=sort_lanes,
                                     update_sort=update_sort)

    return step


class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int


# slot phases
IDLE = "idle"
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class SlotState:
    uid: int = -1
    pos: int = 0                  # next position to write
    generated: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    phase: str = IDLE
    prompt: Optional[np.ndarray] = None
    cursor: int = 0               # prompt tokens prefilled so far

    @property
    def active(self) -> bool:
        """Occupied (prefilling or decoding)."""
        return self.phase != IDLE

    @property
    def decoding(self) -> bool:
        return self.phase == DECODING


class ServeEngine:
    """Slot-based batched serving. Single-host reference implementation —
    the sharded path reuses make_serve_step / make_prefill_chunk_step
    under a mesh (launch.serve)."""

    def __init__(self, params: Any, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 2048, a3: A3Config = A3Config(),
                 greedy: bool = True, resort_every: int = 64,
                 prefill_chunk: Optional[int] = None):
        self.params, self.cfg, self.a3 = params, cfg, a3
        self.max_len = max_len
        self._use_a3 = a3.mode != A3Mode.OFF
        self.resort_every = resort_every
        if prefill_chunk is not None and \
                not decoder.supports_chunked_prefill(cfg):
            prefill_chunk = None      # recurrent blocks: whole-prompt admit
        self.prefill_chunk = prefill_chunk
        self.slots = [SlotState() for _ in range(slots)]
        self.cache = decoder.init_cache(cfg, slots, max_len,
                                        a3=self._use_a3)
        # donate the cache argument: ring buffers update in place (no
        # full-cache copy per tick; the jit aliases input to output).
        self._decode = jax.jit(make_serve_step(cfg, a3),
                               donate_argnums=(1,))
        self._prefill = None
        self._prefill_nosort = None
        if prefill_chunk is not None:
            self._prefill = jax.jit(
                make_prefill_chunk_step(cfg, a3=self._use_a3),
                donate_argnums=(1,))
            if self._use_a3:
                # ticks where no lane finishes its prompt skip the sort
                # AND the per-layer sorted-key passthrough copy
                self._prefill_nosort = jax.jit(
                    make_prefill_chunk_step(cfg, a3=True,
                                            update_sort=False),
                    donate_argnums=(1,))
        self._queue: List[Request] = []
        self._done: Dict[int, List[int]] = {}
        self._uid = 0
        self.greedy = greedy
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "prefill_dispatches": 0,
                      "ticks": 0, "resorts": 0}

    @classmethod
    def from_config(cls, params: Any, cfg: ModelConfig, serve: ServeConfig,
                    a3: A3Config = A3Config()) -> "ServeEngine":
        return cls(params, cfg, slots=serve.slots, max_len=serve.max_len,
                   a3=a3, greedy=serve.greedy,
                   resort_every=serve.resort_every,
                   prefill_chunk=serve.prefill_chunk)

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            # neither admission path supports empty prompts (chunked
            # would fold a reused slot's stale ring into the A^3 sort;
            # whole-prompt prefill has no last position to unembed)
            raise ValueError("empty prompt")
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, prompt, max_new_tokens))
        return uid

    def result(self, uid: int) -> Optional[List[int]]:
        return self._done.get(uid)

    def step(self):
        """One engine tick: admit -> chunked prefill -> resort -> decode."""
        self.stats["ticks"] += 1
        self._admit()
        self._prefill_tick()
        if self._use_a3:
            self._maybe_resort()
        self._advance()

    def _maybe_resort(self):
        """Re-sort a slot's key columns when the exact-tail (tokens
        written since the last sort) grows past ``resort_every`` — the
        serving-time analogue of the paper's comprehension-time
        preprocessing, amortized over ``resort_every`` decode steps.

        All segments' ``sorted_upto`` watermarks come back in one
        ``device_get`` (one host read per tick), and due slots are
        re-sorted together per segment (one batched sort + scatter).
        PREFILLING slots are skipped: the chunked prefill dispatch
        already maintains their sort incrementally."""
        active = [si for si, s in enumerate(self.slots) if s.decoding]
        if not active:
            return
        upto_tree = {name: sc["sorted_upto"]
                     for name, sc in self.cache.items() if "sk_vals" in sc}
        if not upto_tree:
            return
        upto_host = jax.device_get(upto_tree)      # single host read
        for seg_name, upto in upto_host.items():
            due = [si for si in active
                   if self.slots[si].pos - int(upto[0, si])
                   >= self.resort_every]
            if not due:
                continue
            seg_cache = self.cache[seg_name]
            idx = jnp.asarray(due, jnp.int32)
            k_due = seg_cache["k"][:, idx]          # [L, n, Hkv, W, D]
            sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(k_due)
            new_upto = jnp.asarray([self.slots[si].pos for si in due],
                                   jnp.int32)
            self.cache[seg_name] = {
                **seg_cache,
                "sk_vals": seg_cache["sk_vals"].at[:, idx].set(sk.values),
                "sk_rows": seg_cache["sk_rows"].at[:, idx].set(sk.rows),
                "sorted_upto": seg_cache["sorted_upto"].at[:, idx].set(
                    new_upto[None]),
            }
            self.stats["resorts"] += len(due)

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self._queue or any(s.active for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1

    # -- internals ------------------------------------------------------------
    def _admit(self):
        for si, slot in enumerate(self.slots):
            if slot.active or not self._queue:
                continue
            req = self._queue.pop(0)
            if self.prefill_chunk is None:
                self._admit_whole_prompt(si, req)
                continue
            # no host-side cache work at admit: the slot's first chunk
            # dispatch zeroes its ring rows in-graph (pos == 0), so
            # chunked prefill reproduces the whole-prompt cache state.
            self.slots[si] = SlotState(uid=req.uid, pos=0, generated=[],
                                       budget=req.max_new_tokens,
                                       phase=PREFILLING,
                                       prompt=req.prompt, cursor=0)

    def _admit_whole_prompt(self, si: int, req: Request):
        """Legacy per-admit path: one whole-prompt prefill dispatch."""
        s = len(req.prompt)
        toks = jnp.asarray(req.prompt)[None]
        logits, pcache = decoder.prefill(self.params, self.cfg, toks,
                                         max_len=self.max_len,
                                         a3=self._use_a3)
        self._write_slot_cache(si, pcache)
        nxt = int(jnp.argmax(logits[0]))
        self.slots[si] = SlotState(uid=req.uid, pos=s,
                                   generated=[nxt],
                                   budget=req.max_new_tokens - 1,
                                   phase=DECODING)
        self.stats["prefill_tokens"] += s
        self.stats["prefill_dispatches"] += 1
        if self.slots[si].budget <= 0:
            self._finish(si)

    def _prefill_tick(self):
        """Advance every PREFILLING slot by one prompt chunk in a single
        ragged padded dispatch."""
        if self._prefill is None:
            return
        pre = [si for si, s in enumerate(self.slots)
               if s.phase == PREFILLING]
        if not pre:
            return
        n, c = len(self.slots), self.prefill_chunk
        tokens = np.zeros((n, c), np.int32)
        pos = np.zeros((n,), np.int32)
        length = np.zeros((n,), np.int32)
        sort_lanes = np.zeros((n,), bool)
        takes = {}
        for si in pre:
            s = self.slots[si]
            take = min(c, len(s.prompt) - s.cursor)
            tokens[si, :take] = s.prompt[s.cursor:s.cursor + take]
            pos[si] = s.cursor
            length[si] = take
            takes[si] = take
            # A^3 sort amortization: fold into the column sort only on
            # the prompt's final chunk (one sort per admitted prompt).
            sort_lanes[si] = s.cursor + take >= len(s.prompt)
        fn = self._prefill
        if self._prefill_nosort is not None and not sort_lanes.any():
            fn = self._prefill_nosort
        logits, self.cache = fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos), jnp.asarray(length),
            jnp.asarray(sort_lanes))
        self.stats["prefill_dispatches"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for si in pre:
            s = self.slots[si]
            s.cursor += takes[si]
            s.pos = s.cursor
            self.stats["prefill_tokens"] += takes[si]
            if s.cursor >= len(s.prompt):
                s.phase = DECODING
                s.generated = [int(nxt[si])]
                s.budget -= 1
                if s.budget <= 0:
                    self._finish(si)

    def _write_slot_cache(self, si: int, pcache: Dict[str, Any]):
        def write(dst, src):
            return dst.at[:, si:si + 1].set(src)
        self.cache = jax.tree.map(write, self.cache, pcache)

    def _advance(self):
        active = [si for si, s in enumerate(self.slots) if s.decoding]
        if not active:
            return
        # ragged batched decode: every DECODING slot advances in ONE
        # jitted dispatch, each writing its own ring slot at its own
        # position. Idle/prefilling slots ride along at pos=-1: their
        # logits are garbage (ignored) and their ring write is dropped,
        # so mid-prefill cache rows stay intact.
        n = len(self.slots)
        tokens = np.zeros((n,), np.int32)
        pos = np.full((n,), -1, np.int32)
        for si in active:
            tokens[si] = self.slots[si].generated[-1]
            pos[si] = self.slots[si].pos
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos))
        self.stats["decode_steps"] += 1
        self.stats["decode_dispatches"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for si in active:
            slot = self.slots[si]
            slot.generated.append(int(nxt[si]))
            slot.pos += 1
            slot.budget -= 1
            if slot.budget <= 0 or slot.pos >= self.max_len - 1:
                self._finish(si)

    def _finish(self, si: int):
        slot = self.slots[si]
        self._done[slot.uid] = slot.generated
        self.slots[si] = SlotState()
