"""Serving engine: batched prefill + decode with slot-based continuous
batching, and the A^3 approximate decode path.

The engine holds a fixed number of request *slots*. New requests prefill
into a free slot (per-slot prefill keeps the batched decode loop hot);
every ``decode`` call advances all active slots by one token. Slots whose
request finished free up immediately — the decode batch never drains.

Hot-path design (the tick is the latency unit):

* **One dispatch per tick.** ``decode_step`` takes a per-slot position
  vector, so slots at arbitrary position skew (staggered arrivals,
  different prompt lengths) advance in a *single* jitted call — there is
  no group-by-position Python loop and no O(cache) ``jnp.where`` merge.
  ``stats["decode_dispatches"]`` counts jitted decode dispatches; it
  equals ``stats["decode_steps"]`` (ticks that advanced) by construction.
* **Cache donation.** The decode jit donates the KV cache argument
  (``donate_argnums``, as train/step.py does for the train state), so
  the ring buffers are updated in place instead of copied each tick —
  decode stays one HBM sweep of the cache.
* **One host read per tick.** ``_maybe_resort`` fetches all segments'
  ``sorted_upto`` watermarks in a single ``device_get`` and batches the
  re-sorts of all due slots per segment.

A^3 state at serve time: the paper's "comprehension-time" preprocessing
maps to prefill — the prompt's keys are column-sorted once per slot and
reused across all decode steps (amortization argument of SSIV-C). Tokens
generated after prefill form the *fresh tail*, always treated as
candidates (exact attention) until a periodic re-sort folds them in.

``make_serve_step`` builds the jitted decode step used by both the
engine and the multi-pod dry-run (serve_step is what ``decode_*`` shapes
lower).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import A3Config, A3Mode, ModelConfig
from repro.models import decoder


def make_serve_step(
    cfg: ModelConfig,
    a3: A3Config = A3Config(),
    *,
    use_kernel: bool = False,
) -> Callable:
    """Returns step(params, cache, token [B], pos scalar or [B]) ->
    (logits [B, Vp], new_cache)."""

    def step(params, cache, token, pos):
        return decoder.decode_step(params, cfg, cache, token, pos, a3=a3,
                                   use_kernel=use_kernel)

    return step


class Request(NamedTuple):
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int


@dataclasses.dataclass
class SlotState:
    uid: int = -1
    pos: int = 0                  # next position to write
    generated: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    active: bool = False


class ServeEngine:
    """Slot-based batched serving. Single-host reference implementation —
    the sharded path reuses make_serve_step under a mesh (launch.serve)."""

    def __init__(self, params: Any, cfg: ModelConfig, *, slots: int = 4,
                 max_len: int = 2048, a3: A3Config = A3Config(),
                 greedy: bool = True, resort_every: int = 64):
        self.params, self.cfg, self.a3 = params, cfg, a3
        self.max_len = max_len
        self._use_a3 = a3.mode != A3Mode.OFF
        self.resort_every = resort_every
        self.slots = [SlotState() for _ in range(slots)]
        self.cache = decoder.init_cache(cfg, slots, max_len,
                                        a3=self._use_a3)
        # donate the cache argument: ring buffers update in place (no
        # full-cache copy per tick; the jit aliases input to output).
        self._decode = jax.jit(make_serve_step(cfg, a3),
                               donate_argnums=(1,))
        self._queue: List[Request] = []
        self._done: Dict[int, List[int]] = {}
        self._uid = 0
        self.greedy = greedy
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_dispatches": 0, "resorts": 0}

    # -- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32) -> int:
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, np.asarray(prompt, np.int32),
                                   max_new_tokens))
        return uid

    def result(self, uid: int) -> Optional[List[int]]:
        return self._done.get(uid)

    def step(self):
        """One engine tick: admit queued requests, advance decode."""
        self._admit()
        if self._use_a3:
            self._maybe_resort()
        self._advance()

    def _maybe_resort(self):
        """Re-sort a slot's key columns when the exact-tail (tokens
        written since the last sort) grows past ``resort_every`` — the
        serving-time analogue of the paper's comprehension-time
        preprocessing, amortized over ``resort_every`` decode steps.

        All segments' ``sorted_upto`` watermarks come back in one
        ``device_get`` (one host read per tick), and due slots are
        re-sorted together per segment (one batched sort + scatter)."""
        active = [si for si, s in enumerate(self.slots) if s.active]
        if not active:
            return
        upto_tree = {name: sc["sorted_upto"]
                     for name, sc in self.cache.items() if "sk_vals" in sc}
        if not upto_tree:
            return
        upto_host = jax.device_get(upto_tree)      # single host read
        from repro.core.candidate_selection import sort_key_columns
        for seg_name, upto in upto_host.items():
            due = [si for si in active
                   if self.slots[si].pos - int(upto[0, si])
                   >= self.resort_every]
            if not due:
                continue
            seg_cache = self.cache[seg_name]
            idx = jnp.asarray(due, jnp.int32)
            k_due = seg_cache["k"][:, idx]          # [L, n, Hkv, W, D]
            sk = jax.vmap(jax.vmap(jax.vmap(sort_key_columns)))(k_due)
            new_upto = jnp.asarray([self.slots[si].pos for si in due],
                                   jnp.int32)
            self.cache[seg_name] = {
                **seg_cache,
                "sk_vals": seg_cache["sk_vals"].at[:, idx].set(sk.values),
                "sk_rows": seg_cache["sk_rows"].at[:, idx].set(sk.rows),
                "sorted_upto": seg_cache["sorted_upto"].at[:, idx].set(
                    new_upto[None]),
            }
            self.stats["resorts"] += len(due)

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self._queue or any(s.active for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1

    # -- internals ------------------------------------------------------------
    def _admit(self):
        for si, slot in enumerate(self.slots):
            if slot.active or not self._queue:
                continue
            req = self._queue.pop(0)
            s = len(req.prompt)
            toks = jnp.asarray(req.prompt)[None]
            # per-slot prefill: fill this slot's cache rows (comprehension
            # time: includes the A^3 column sort when approximating)
            logits, pcache = decoder.prefill(self.params, self.cfg, toks,
                                             max_len=self.max_len,
                                             a3=self._use_a3)
            self._write_slot_cache(si, pcache)
            nxt = int(jnp.argmax(logits[0]))
            self.slots[si] = SlotState(uid=req.uid, pos=s,
                                       generated=[nxt],
                                       budget=req.max_new_tokens - 1,
                                       active=True)
            self.stats["prefill_tokens"] += s
            if self.slots[si].budget <= 0:
                self._finish(si)

    def _write_slot_cache(self, si: int, pcache: Dict[str, Any]):
        def write(dst, src):
            return dst.at[:, si:si + 1].set(src)
        self.cache = jax.tree.map(write, self.cache, pcache)

    def _advance(self):
        active = [si for si, s in enumerate(self.slots) if s.active]
        if not active:
            return
        # ragged batched decode: every active slot advances in ONE jitted
        # dispatch, each writing its own ring slot at its own position.
        # Inactive slots decode garbage at pos 0 (ignored; their cache
        # rows are fully overwritten at admit).
        n = len(self.slots)
        tokens = np.zeros((n,), np.int32)
        pos = np.zeros((n,), np.int32)
        for si in active:
            tokens[si] = self.slots[si].generated[-1]
            pos[si] = self.slots[si].pos
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos))
        self.stats["decode_steps"] += 1
        self.stats["decode_dispatches"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for si in active:
            slot = self.slots[si]
            slot.generated.append(int(nxt[si]))
            slot.pos += 1
            slot.budget -= 1
            if slot.budget <= 0 or slot.pos >= self.max_len - 1:
                self._finish(si)

    def _finish(self, si: int):
        slot = self.slots[si]
        self._done[slot.uid] = slot.generated
        self.slots[si] = SlotState()
