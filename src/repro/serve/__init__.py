from repro.serve.chaos import ChaosConfig, ChaosError, ChaosInjector
from repro.serve.engine import ServeEngine, make_decode_block_step, \
    make_serve_step
from repro.serve.prefix_cache import PrefixCache

__all__ = ["ChaosConfig", "ChaosError", "ChaosInjector", "PrefixCache",
           "ServeEngine", "make_decode_block_step", "make_serve_step"]
