from repro.serve.chaos import ChaosConfig, ChaosError, ChaosInjector, \
    EngineCrash
from repro.serve.engine import ServeEngine, make_decode_block_step, \
    make_serve_step
from repro.serve.page_store import CheckpointError, IntegrityError, PageStore
from repro.serve.prefix_cache import PrefixCache

__all__ = ["ChaosConfig", "ChaosError", "ChaosInjector", "CheckpointError",
           "EngineCrash", "IntegrityError", "PageStore", "PrefixCache",
           "ServeEngine", "make_decode_block_step", "make_serve_step"]
