from repro.serve.engine import ServeEngine, make_decode_block_step, \
    make_serve_step

__all__ = ["ServeEngine", "make_decode_block_step", "make_serve_step"]
