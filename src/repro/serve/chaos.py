"""Deterministic chaos injection for the serving engine.

Fault tolerance is only as real as the faults it has been shown to
survive. This module is the serving-plane counterpart of
``train/fault.py``: a *seeded* injector the engine consults at fixed
tick phases, so a chaos run is exactly reproducible from
``(seed, rate)`` — the conformance tests replay the same fault schedule
against the same workload and assert fault *isolation* (un-injected
requests are token-for-token identical to a chaos-free run) the same
way PRs 2-5 asserted correctness.

Injection sites (each independently decided per tick from a counter-
based RNG keyed on ``(seed, tick, site)`` — no shared stream, so adding
or removing a site never reshuffles the others):

* ``corrupt`` — pick one DECODING lane and overwrite its per-segment
  mixer state with NaN (:func:`corrupt_cache_lane`). The lane's next
  logits go non-finite, the decode dispatch emits the ``POISON``
  sentinel in the token ring (``decoder.POISON``), and the engine
  quarantines the request as FAILED off the *existing* per-block
  harvest — detection costs no extra host sync.
* ``gather`` — fail a warm admission's prefix-cache page gather
  (:class:`ChaosError` raised before the copy dispatch, so the device
  cache is untouched and no trie refs leak).
* ``raise`` / ``delay`` — abort or stall a tick at a phase boundary
  (``tick_start`` / ``pre_prefill`` / ``pre_advance``), exercising the
  engine's mid-tick recovery (leftover device-resident handoff tokens
  must be flushed, not overwritten). A ``delay`` firing accrues
  ``delay_ticks`` onto a *virtual* stall counter the engine consults at
  each tick start (:meth:`ChaosInjector.consume_delay`) — no
  ``time.sleep``, so chaos runs are wall-clock-independent and the
  ``(seed, tick, site)`` schedule is exact in CI.
* ``spill`` — force-evict LRU prefix-cache pages at a tick boundary
  (:meth:`ChaosInjector.pick_spill`), demoting them to the host-RAM L2
  tier: exercises the demote -> promote round trip under pressure.
* ``restore_corrupt`` — flip a byte of an L2 blob immediately before
  its verified restore (the engine wires this as the prefix cache's
  ``l2_fault_hook``): the checksum must catch it and the node must
  degrade to cold prefill, never to wrong tokens.
* ``crash`` — kill the engine at a phase boundary
  (:class:`EngineCrash`, *not* absorbed by ``run_to_completion``):
  stands in for process death. The recovery story is
  ``ServeEngine.checkpoint`` / ``ServeEngine.restore`` — the chaos
  harness proves token-for-token continuation from the last durable
  checkpoint.

``max_injections`` caps the *fault* sites (corrupt + gather +
restore_corrupt) so a test can pin "exactly N injections" and "exactly
N request victims" deterministically (restore_corrupt never makes a
request a victim — it degrades a cache node, not a request).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ChaosConfig", "ChaosError", "ChaosInjector", "EngineCrash",
           "corrupt_cache_lane"]


class ChaosError(RuntimeError):
    """A deliberately injected fault (stands in for a device error,
    preempted host, or corrupted transfer mid-tick)."""


class EngineCrash(ChaosError):
    """An injected process death mid-tick. Unlike a plain ChaosError it
    propagates out of ``run_to_completion`` — recovery means restoring
    a fresh engine from the last checkpoint, not ticking on."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Injection schedule knobs. ``rate`` is the per-site, per-tick
    firing probability; every decision is a pure function of
    ``(seed, tick, site)``."""
    seed: int = 0
    rate: float = 0.0
    # fault sites (terminal for the victim request)
    corrupt_logits: bool = True
    fail_gather: bool = True
    # durable-state fault site: corrupt an L2 blob before its restore
    # (non-terminal — the node degrades to cold prefill)
    restore_corrupt: bool = False
    # disruption sites (abort/stall a tick; no request is a victim)
    raise_mid_tick: bool = True
    delay_mid_tick: bool = False
    # virtual ticks a fired delay stalls the engine for (consumed at
    # tick starts — no wall clock involved)
    delay_ticks: int = 1
    # force-evict (demote-to-L2) up to this many LRU prefix-cache
    # pages when the spill site fires (0 disables the site)
    spill_pages: int = 0
    # kill the engine at a phase boundary (EngineCrash propagates out
    # of run_to_completion; recovery = checkpoint/restore)
    crash_mid_tick: bool = False
    # cap on total corrupt + gather + restore_corrupt injections
    # (None = unlimited)
    max_injections: Optional[int] = None

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if self.delay_ticks < 0:
            raise ValueError(f"delay_ticks must be >= 0, got "
                             f"{self.delay_ticks}")
        if self.spill_pages < 0:
            raise ValueError(f"spill_pages must be >= 0, got "
                             f"{self.spill_pages}")
        if self.max_injections is not None and self.max_injections < 0:
            raise ValueError(f"max_injections must be >= 0, got "
                             f"{self.max_injections}")


def corrupt_cache_lane(cache: Dict[str, Any], si: int) -> Dict[str, Any]:
    """NaN every floating-point leaf of lane ``si`` across all segment
    states (every cache leaf is ``[L, B, ...]`` — batch axis 1).
    Integer leaves (sk_rows, sorted_upto watermarks) are left intact:
    the fault model is corrupted *values*, and the poison detector keys
    on non-finite logits, which integer bookkeeping cannot produce."""
    def poison(x):
        if isinstance(x, (jax.Array, np.ndarray)) \
                and jnp.issubdtype(x.dtype, jnp.floating):
            return x.at[:, si].set(jnp.nan)
        return x
    return jax.tree_util.tree_map(poison, cache)


class ChaosInjector:
    """Engine-facing injector. The engine calls :meth:`phase` at tick
    phase boundaries, :meth:`pick_corrupt_victim` before each decode
    dispatch, and passes :meth:`gather_fail` as the prefix-cache
    admission hook. ``events`` records every injection as
    ``(kind, tick, detail)``; :attr:`injected_uids` is the set of
    request uids a fault site made victims (the conformance tests'
    ground truth for who must terminate FAILED)."""

    def __init__(self, config: ChaosConfig = ChaosConfig()):
        self.config = config
        self.events: List[Tuple[str, int, Any]] = []
        self._faults = 0
        self._delay_pending = 0

    # -- determinism core ----------------------------------------------------
    def _rng(self, tick: int, site: str) -> np.random.Generator:
        # counter-based: an independent generator per (seed, tick, site)
        key = [int(self.config.seed), int(tick)] + [ord(c) for c in site]
        return np.random.default_rng(key)

    def _fault_budget_left(self) -> bool:
        mi = self.config.max_injections
        return mi is None or self._faults < mi

    @property
    def injected_uids(self) -> set:
        """Uids made victims by a fault site (corrupt / gather_fail)."""
        return {d for k, _, d in self.events
                if k in ("corrupt", "gather_fail")}

    # -- engine hooks --------------------------------------------------------
    def phase(self, tick: int, name: str) -> None:
        """Called at a tick phase boundary; may accrue a virtual stall
        (``delay``), kill the engine (``crash`` — EngineCrash, the
        checkpoint/restore harness's trigger), or abort the tick
        (``raise`` — the engine counts the aborted tick and recovers on
        the next one)."""
        c = self.config
        if c.delay_mid_tick \
                and self._rng(tick, "delay:" + name).random() < c.rate:
            self.events.append(("delay", tick, name))
            self._delay_pending += c.delay_ticks
        if c.crash_mid_tick \
                and self._rng(tick, "crash:" + name).random() < c.rate:
            self.events.append(("crash", tick, name))
            raise EngineCrash(f"injected engine crash at {name} "
                              f"(tick {tick})")
        if c.raise_mid_tick \
                and self._rng(tick, "raise:" + name).random() < c.rate:
            self.events.append(("raise", tick, name))
            raise ChaosError(f"injected tick abort at {name} "
                             f"(tick {tick})")

    def consume_delay(self) -> bool:
        """Engine tick-start hook for the virtual delay counter: True
        means this tick is a stall (the engine does no work and counts
        ``stats["chaos_delayed_ticks"]``). Deterministic — the pending
        count is a pure function of the fired delay events."""
        if self._delay_pending <= 0:
            return False
        self._delay_pending -= 1
        return True

    def pick_spill(self, tick: int) -> int:
        """Maybe force-evict prefix-cache pages this tick (demoting
        them to the L2 tier). Returns how many pages to spill."""
        c = self.config
        if c.spill_pages <= 0:
            return 0
        rng = self._rng(tick, "spill")
        if rng.random() >= c.rate:
            return 0
        n = int(rng.integers(1, c.spill_pages + 1))
        self.events.append(("spill", tick, n))
        return n

    def l2_restore_corrupt(self, tick: int,
                           key: Sequence[int]) -> bool:
        """Prefix-cache ``l2_fault_hook``: called with the blob key
        before each L2 restore; True corrupts the blob first (the
        checksum must then catch it — graceful degradation, counted in
        ``stats["l2_integrity_drops"]``, never wrong tokens). Keyed on
        the blob key contents so multiple promotions in one tick draw
        independently."""
        c = self.config
        if not c.restore_corrupt or not self._fault_budget_left():
            return False
        site = f"l2corrupt:{len(key)}:{sum(key) % 65536}"
        if self._rng(tick, site).random() >= c.rate:
            return False
        self._faults += 1
        self.events.append(("restore_corrupt", tick, tuple(key)))
        return True

    def pick_corrupt_victim(self, tick: int,
                            uids: Sequence[int]) -> Optional[int]:
        """Maybe pick one decoding request whose lane state the engine
        should corrupt this tick. Returns the victim uid or None."""
        if not self.config.corrupt_logits or not uids \
                or not self._fault_budget_left():
            return None
        rng = self._rng(tick, "corrupt")
        if rng.random() >= self.config.rate:
            return None
        uid = int(sorted(uids)[int(rng.integers(len(uids)))])
        self._faults += 1
        self.events.append(("corrupt", tick, uid))
        return uid

    def gather_fail(self, tick: int, uid: int, matched: int) -> None:
        """Prefix-cache admission hook: called for warm admissions
        (``matched`` > 0 reused tokens) *before* the gather dispatch.
        Raises :class:`ChaosError` to fail the gather."""
        if not self.config.fail_gather or not self._fault_budget_left():
            return
        if self._rng(tick, f"gather:{uid}").random() < self.config.rate:
            self._faults += 1
            self.events.append(("gather_fail", tick, uid))
            raise ChaosError(f"injected page-gather failure for uid "
                             f"{uid} ({matched} matched tokens, tick "
                             f"{tick})")
